"""Serving launcher: batched requests through the iCh chunked-prefill engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 8
"""
import argparse

import jax
import numpy as np

from ..configs import get_arch, reduced
from ..models import model as M
from ..serve.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0),
                           max_seq=args.prompt_len + args.new_tokens + 8)
    eng = Engine(cfg, params,
                 EngineConfig(max_seq=args.prompt_len + args.new_tokens + 8))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size - 1, (args.requests, args.prompt_len)).astype(np.int32)
    out, stats = eng.generate(prompts, n_new=args.new_tokens)
    tok_s = out.size / max(sum(c["dt"] for c in stats["chunks"]), 1e-9)
    print(f"[serve] {args.requests} reqs x {args.new_tokens} new tokens; "
          f"chunks {[c['chunk'] for c in stats['chunks']]}; d={stats['d_final']}")


if __name__ == "__main__":
    main()
