"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100 \
      [--preset tiny|full] [--ckpt-dir DIR]

On a real multi-host TPU slice this process is started per host (jax
distributed init is environment-driven); XLA latency-hiding flags below
enable compute/collective overlap for the FSDP gathers.
"""
import argparse
import os

# Collective/compute overlap (latency-hiding scheduler) — the standard
# production flags; harmless on CPU.
os.environ.setdefault("XLA_FLAGS", " ".join([
    "--xla_gpu_enable_latency_hiding_scheduler=true",
]) if False else os.environ.get("XLA_FLAGS", ""))

from ..configs import get_arch, reduced
from ..train.trainer import RunConfig, train
from ..train.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    tcfg = TrainConfig(bf16_params=args.bf16_params,
                       grad_compress=args.grad_compress,
                       microbatch=args.microbatch)
    run = RunConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                    ckpt_dir=args.ckpt_dir)
    _, losses = train(cfg, run, tcfg)
    print(f"[train] {args.arch}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
