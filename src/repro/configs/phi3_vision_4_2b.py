"""phi-3-vision-4.2b [vlm]: 32L, d=3072, 32H (kv=32), ff=8192, vocab=32064.
phi3-mini backbone + CLIP frontend; the vision tower is a STUB (input_specs
provides precomputed patch embeddings). [hf:microsoft/Phi-3-vision-128k-instruct]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    num_patches=576,
    train_microbatch=4,
)
