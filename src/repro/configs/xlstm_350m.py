"""xlstm-350m [ssm]: 24L, d=1024, 4H (kv=4), no FFN (d_ff=0), vocab=50304.
sLSTM + mLSTM blocks (every 4th block is sLSTM). Fully recurrent =>
long_500k runs. [arXiv:2405.04517]"""
from .base import ArchConfig

_pattern = tuple("S" if (i % 4 == 3) else "X" for i in range(24))

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=_pattern, scan_layers=False,
    train_microbatch=16,
)
