"""zamba2-1.2b [hybrid]: 38L, d=2048, 32H (kv=32), ff=8192, vocab=32000,
ssm_state=64. Mamba2 backbone with a SHARED attention block applied every
6th layer (weight-tied). Attention blocks use a 4096 sliding window at long
context (sub-quadratic => long_500k runs). [arXiv:2411.15242]"""
from .base import ArchConfig

_pattern = tuple("A" if (i % 6 == 5) else "M" for i in range(38))

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, block_pattern=_pattern, shared_attention=True,
    attn_window=4096, scan_layers=False,
    train_microbatch=16,
)
