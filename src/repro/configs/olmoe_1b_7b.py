"""olmoe-1b-7b [moe]: 16L, d=2048, 16H (kv=16), expert ff=1024,
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    moe=True, n_experts=64, experts_per_token=8, moe_d_ff=1024,
)
