"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; every assigned input shape
is a ``ShapeSpec``. The (arch x shape) grid drives smoke tests, the multi-pod
dry-run, and the roofline table.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shapes (decode_* and long_* lower serve_step).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # MoE
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert FFN width (fine-grained for deepseek)
    dense_d_ff: int = 0  # FFN width of leading dense layers (deepseek layer 0)
    moe_layer_start: int = 0  # layers [0, start) use a dense FFN
    moe_cmax_factor: float = 2.0  # compiled expert buffer = factor * C_base

    # hybrid / ssm (zamba2 / xlstm)
    ssm_state: int = 0
    block_pattern: tuple = ()  # per-layer mixer kind: "A"ttn / "M"amba / "X"=mLSTM / "S"=sLSTM
    shared_attention: bool = False  # zamba2: one attn param set reused at every "A"
    ssm_head_dim: int = 64
    mamba_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 256  # SSD/mLSTM chunk length (memory-term lever, §Perf)

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed audio frames (stub frontend)

    # vlm (phi-3-vision)
    num_patches: int = 0  # precomputed patch embeddings (stub frontend)

    # long-context behaviour: "full" attention archs skip long_500k;
    # hybrids use a sliding window for their attention blocks.
    attn_window: int = 0  # 0 = full causal; >0 = sliding window

    # distribution knobs (overridable per run)
    scan_layers: bool = True  # stack homogeneous layers and lax.scan
    remat: bool = True
    remat_policy: str = "nothing"  # see models.model.REMAT_POLICIES
    train_microbatch: int = 1  # grad-accumulation steps at train_4k scale

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a multiple of 256 so the embedding
        table shards evenly over the model axis."""
        return ((self.vocab_size + 255) // 256) * 256

    def supports(self, shape: ShapeSpec) -> bool:
        """long_500k needs sub-quadratic sequence mixing (DESIGN.md §5)."""
        if shape.name == "long_500k":
            return self.family in ("hybrid", "ssm")
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6*N*D and for sanity tests."""
        d, dh = self.d_model, self.dh
        V = self.padded_vocab
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d  # lm head

        def attn_params():
            qkv = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
            if self.qkv_bias:
                qkv += self.n_heads * dh + 2 * self.n_kv_heads * dh
            return qkv + (self.n_heads * dh) * d

        def dense_ffn(f):
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * f

        def norms():
            if self.norm == "nonparametric_ln":
                return 0
            w = 2 * d
            return w * (2 if self.norm == "layernorm" else 1)

        if self.family == "encdec":
            # encoder layers: self-attn + ffn; decoder: self + cross + ffn
            enc = self.encoder_layers * (attn_params() + dense_ffn(self.d_ff) + norms())
            dec = self.n_layers * (2 * attn_params() + dense_ffn(self.d_ff) + int(1.5 * norms()))
            return n + enc + dec

        if self.family in ("hybrid", "ssm"):
            total = n
            d_in = self.mamba_expand * d
            attn_done = False
            for kind in self.block_pattern:
                if kind == "A":
                    if self.shared_attention and attn_done:
                        continue
                    total += attn_params() + dense_ffn(self.d_ff) + norms()
                    attn_done = True
                elif kind == "M":  # mamba2
                    nheads_m = d_in // self.ssm_head_dim
                    total += d * (2 * d_in + 2 * self.ssm_state + nheads_m)  # in_proj
                    total += self.conv_kernel * (d_in + 2 * self.ssm_state)
                    total += 2 * nheads_m  # A, D
                    total += d_in * d  # out_proj
                    total += d  # norm
                elif kind in ("X", "S"):  # mLSTM / sLSTM
                    total += d * (2 * d_in) + 3 * d_in * self.n_heads  # proj + gates (approx)
                    total += 3 * d_in * d_in // self.n_heads if kind == "X" else 4 * d_in
                    total += d_in * d + d
            return total

        per_layer = attn_params() + norms()
        total = n
        for layer in range(self.n_layers):
            if self.moe and layer >= self.moe_layer_start:
                fe = self.moe_d_ff
                experts = (self.n_experts + self.n_shared_experts) * dense_ffn(fe) // 3 * 3
                experts = (self.n_experts + self.n_shared_experts) * (3 * d * fe if self.act == "swiglu" else 2 * d * fe)
                total += per_layer + experts + d * self.n_experts  # + router
            elif self.moe:
                total += per_layer + dense_ffn(self.dense_d_ff or self.d_ff)
            else:
                total += per_layer + dense_ffn(self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        fe = self.moe_d_ff
        per_tok_experts = (self.experts_per_token + self.n_shared_experts)
        all_experts = (self.n_experts + self.n_shared_experts)
        mult = 3 if self.act == "swiglu" else 2
        moe_layers = self.n_layers - self.moe_layer_start
        inactive = moe_layers * (all_experts - per_tok_experts) * mult * d * fe
        return self.param_count() - inactive
