"""whisper-small [audio]: 12L enc + 12L dec, d=768, 12H (kv=12), ff=3072,
vocab=51865. Encoder-decoder; conv/audio frontend is a STUB (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    norm="layernorm", act="gelu",
    encoder_layers=12, encoder_seq=1500,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
)
