"""deepseek-moe-16b [moe]: 28L, d=2048, 16H (kv=16), expert ff=1408,
vocab=102400, 2 shared + 64 routed top-6, fine-grained experts; layer 0 is a
dense FFN (DeepSeekMoE design). [arXiv:2401.06066]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=True, n_experts=64, experts_per_token=6, n_shared_experts=2,
    moe_d_ff=1408, dense_d_ff=11264, moe_layer_start=1,
    train_microbatch=2,
)
