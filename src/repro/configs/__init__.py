"""Assigned architecture registry: ``--arch <id>`` resolves here."""
from .base import ArchConfig, ShapeSpec, SHAPES
from . import (
    whisper_small, olmoe_1b_7b, deepseek_moe_16b, phi3_vision_4_2b,
    phi3_medium_14b, glm4_9b, olmo_1b, qwen2_1_5b, zamba2_1_2b, xlstm_350m,
)

ARCHS: dict[str, ArchConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        whisper_small, olmoe_1b_7b, deepseek_moe_16b, phi3_vision_4_2b,
        phi3_medium_14b, glm4_9b, olmo_1b, qwen2_1_5b, zamba2_1_2b,
        xlstm_350m,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A small same-family config for CPU smoke tests."""
    import dataclasses as _dc
    small = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0, vocab_size=512,
        scan_layers=cfg.scan_layers, remat=False,
    )
    if cfg.moe:
        small.update(n_experts=4, experts_per_token=2, moe_d_ff=64,
                     dense_d_ff=128 if cfg.dense_d_ff else 0)
    if cfg.block_pattern:
        small["block_pattern"] = cfg.block_pattern[:2]
        small["n_layers"] = 2
    if cfg.family == "encdec":
        small.update(encoder_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        small.update(num_patches=8)
    if cfg.ssm_state:
        small.update(ssm_state=16)
    small.update(overrides)
    return _dc.replace(cfg, **small)
