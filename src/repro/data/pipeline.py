"""Input pipeline with the iCh data dispatcher (straggler mitigation).

The cross-host analogue of the paper's runtime: the global batch is a loop
over example shards; each ingest host owns a contiguous shard range
(distributed queues), sizes its read-ahead chunk with iCh's adaptive rule
(throughput classification against the running mean of examples ingested),
and idle hosts STEAL shard ranges from stragglers (slow disks / hot nodes).
This uses the real threaded executor from core/ — it is the same code the
paper evaluation validates, applied to data loading.

The tokens themselves are synthetic (seeded LM-ish integer streams) so the
end-to-end examples run hermetically.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from ..sched.data_sched import ShardDispatcher
from ..sched.defaults import ICH_EPS


def synthetic_tokens(batch: int, seq: int, vocab: int, step: int,
                     seed: int = 0) -> dict:
    """Deterministic pseudo-corpus: Zipf-ish unigram stream + shifted labels."""
    rng = np.random.default_rng(seed + step)
    ranks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = np.minimum(ranks, vocab - 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class HostIngestStats:
    chunks: int = 0
    steals: int = 0


class IChDataDispatcher(ShardDispatcher):
    """Dispatch `n_examples` ingest work items across `n_hosts` worker
    threads under the iCh policy (adaptive chunk + stealing). Thin wrapper
    over the scheduler API's dispatch layer (`repro/sched/data_sched.py`)."""

    def __init__(self, n_hosts: int = 4, eps: float = ICH_EPS):
        super().__init__(n_hosts=n_hosts, eps=eps)

    def ingest(self, n_examples: int, read_fn) -> HostIngestStats:
        """read_fn(i) ingests example i (exactly once, any host)."""
        stats = self.dispatch(n_examples, read_fn)
        return HostIngestStats(chunks=stats.chunks, steals=stats.steals)


class Pipeline:
    """Double-buffered synthetic pipeline: batch t+1 is assembled (via the
    iCh dispatcher) while batch t trains."""

    def __init__(self, cfg, batch: int, seq: int, n_hosts: int = 4, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.dispatcher = IChDataDispatcher(n_hosts)
        self._next = None
        self._thread = None
        self._start(0)

    def _assemble(self, step: int):
        out = synthetic_tokens(self.batch, self.seq, self.cfg.padded_vocab,
                               step, self.seed)
        buf = {"tokens": np.zeros_like(out["tokens"]),
               "labels": np.zeros_like(out["labels"])}

        def read(i):  # per-example ingest work item
            buf["tokens"][i] = out["tokens"][i]
            buf["labels"][i] = out["labels"][i]

        stats = self.dispatcher.ingest(self.batch, read)
        self._next = (buf, stats)

    def _start(self, step: int):
        self._thread = threading.Thread(target=self._assemble, args=(step,))
        self._thread.start()

    def get_batch(self, step: int):
        self._thread.join()
        batch, stats = self._next
        self._start(step + 1)
        return batch, stats
